"""Sharded serving: the host-mesh builders, the occupancy-aware router
(``repro.serve.router.ShardedEngine``), cross-shard preempt/resume token
identity, aggregated backpressure, steady-state compile discipline, and
the forced-4-device end-to-end path.

Most router logic is exercised IN-PROCESS by pinning several shards to
the single CPU device (``devices=[dev, dev]`` — placement, global slot
numbering, preemption forwarding, and the per-shard compile accounting
are all host-side and device-count-independent).  The real multi-device
behavior needs ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
set before the backend initializes, so it runs in a subprocess.
"""

import dataclasses
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.guards import no_recompile
from repro.configs import ARCHITECTURES
from repro.launch.mesh import HOST_DEVICES_ENV, host_devices, make_host_mesh
from repro.launch.serve import generate_reference
from repro.models import lm
from repro.net import ChaosSchedule, block_pool_squeeze
from repro.net.chaos import EngineChaos
from repro.serve import (
    PoolConfig,
    PoolExhausted,
    ShardedEngine,
    SLA,
    SLAScheduler,
)

REPO_ROOT = Path(__file__).resolve().parent.parent


def _setup(channel="iid", loss_rate=0.3, **overrides):
    cfg = ARCHITECTURES["qwen1.5-0.5b"].reduced(
        attn_impl="flash_decode", **overrides
    )
    cfg = cfg.with_updates(
        link=dataclasses.replace(cfg.link, loss_rate=loss_rate,
                                 channel=channel)
    )
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompt(i, length, vocab):
    return np.asarray(
        jax.random.randint(
            jax.random.fold_in(jax.random.PRNGKey(7), i), (length,), 0,
            vocab, jnp.int32,
        )
    )


def _two_shard(cfg, pool):
    dev = jax.devices()[0]
    return ShardedEngine(cfg, pool, devices=[dev, dev])


def _check_reference(cfg, params, reqs, base_key):
    for i, req in enumerate(reqs):
        ref, _ = generate_reference(
            params, cfg, req.prompt[None], req.max_tokens,
            key=jax.random.fold_in(base_key, i),
        )
        np.testing.assert_array_equal(req.tokens, np.asarray(ref)[0])


# ---------------------------------------------------------------------------
# launch.mesh: deterministic host meshes + overrides (satellite)
# ---------------------------------------------------------------------------


class TestHostMesh:
    def test_explicit_devices_win(self):
        devs = jax.devices()
        mesh = make_host_mesh(devices=devs[:1])
        assert mesh.axis_names == ("data", "model")
        assert mesh.shape == {"data": 1, "model": 1}

    def test_empty_devices_rejected(self):
        with pytest.raises(ValueError, match="empty"):
            host_devices([])

    def test_model_axis_must_divide(self):
        with pytest.raises(ValueError, match="does not divide"):
            make_host_mesh(3, devices=jax.devices()[:1])

    def test_model_axis_positive(self):
        with pytest.raises(ValueError, match=">= 1"):
            make_host_mesh(0, devices=jax.devices()[:1])

    def test_env_override_too_many_fails_loudly(self, monkeypatch):
        monkeypatch.setenv(HOST_DEVICES_ENV, str(len(jax.devices()) + 1))
        with pytest.raises(ValueError, match="xla_force_host_platform"):
            host_devices()

    def test_env_override_negative_rejected(self, monkeypatch):
        monkeypatch.setenv(HOST_DEVICES_ENV, "-2")
        with pytest.raises(ValueError, match=">= 0"):
            host_devices()

    def test_env_override_selects_prefix(self, monkeypatch):
        monkeypatch.setenv(HOST_DEVICES_ENV, "1")
        assert host_devices() == jax.devices()[:1]


# ---------------------------------------------------------------------------
# Router: placement-invariant token identity + per-shard compile contract
# ---------------------------------------------------------------------------


class TestRouterTokenIdentity:
    @pytest.mark.parametrize("channel", ["iid", "ge"])
    def test_matches_reference_across_shards(self, channel):
        cfg, params = _setup(channel=channel)
        eng = _two_shard(cfg, PoolConfig(max_slots=2, max_new=8,
                                         max_prompt=16))
        base = jax.random.PRNGKey(42)
        lengths = (5, 9, 12, 7, 16)
        reqs = [
            eng.submit(_prompt(i, n, cfg.vocab_size), 6,
                       key=jax.random.fold_in(base, i))
            for i, n in enumerate(lengths)
        ]
        done = eng.run(params)
        assert len(done) == len(lengths)
        # Both shards must actually have served traffic, or the test says
        # nothing about placement invariance.
        assert all(c > 0 for c in eng.placement_counts), \
            eng.placement_counts
        _check_reference(cfg, params, reqs, base)

    def test_int8_kv_cache(self):
        cfg, params = _setup(kv_cache_dtype="int8")
        eng = _two_shard(cfg, PoolConfig(max_slots=2, max_new=8,
                                         max_prompt=16))
        base = jax.random.PRNGKey(3)
        reqs = [
            eng.submit(_prompt(i, n, cfg.vocab_size), 5,
                       key=jax.random.fold_in(base, i))
            for i, n in enumerate((6, 11, 14))
        ]
        eng.run(params)
        _check_reference(cfg, params, reqs, base)

    def test_per_shard_compiles_is_buckets_plus_one(self):
        cfg, params = _setup()
        eng = _two_shard(cfg, PoolConfig(max_slots=2, max_new=8,
                                         max_prompt=16))
        base = jax.random.PRNGKey(1)
        for i, n in enumerate((5, 9, 12, 7)):     # buckets 8 and 16
            eng.submit(_prompt(i, n, cfg.vocab_size), 4,
                       key=jax.random.fold_in(base, i))
        eng.run(params)
        for sh in eng.shards:
            assert sh.num_buckets == 2
            assert sh.compiles == sh.num_buckets + 1, (
                sh.compiles, sh.num_buckets
            )
        assert eng.compiles == sum(sh.compiles for sh in eng.shards)

    def test_placement_prefers_freest_shard(self):
        """With shard0 loaded and shard1 idle, the next admission must go
        to shard1; ties break toward the lower index."""
        cfg, params = _setup()
        eng = _two_shard(cfg, PoolConfig(max_slots=2, max_new=8,
                                         max_prompt=16))
        base = jax.random.PRNGKey(9)
        r0 = eng.submit(_prompt(0, 8, cfg.vocab_size), 8, key=base)
        eng.step(params)                     # admit r0 (tie -> shard 0)
        assert eng.placements[r0.rid] == [0]
        r1 = eng.submit(_prompt(1, 8, cfg.vocab_size), 8,
                        key=jax.random.fold_in(base, 1))
        eng.step(params)                     # shard1 now strictly freer
        assert eng.placements[r1.rid] == [1]
        eng.run(params)


# ---------------------------------------------------------------------------
# Cross-shard preempt/resume (scheduler-driven) token identity
# ---------------------------------------------------------------------------


class TestCrossShardPreemptResume:
    @pytest.mark.parametrize(
        "channel,overrides",
        [("iid", {}), ("ge", {}), ("iid", {"kv_cache_dtype": "int8"})],
        ids=["iid", "ge", "int8"],
    )
    def test_preempt_on_a_resume_on_b(self, channel, overrides):
        """Preempt a request off shard 0 and let it resume on shard 1:
        the keyed math is placement-invariant, so tokens must equal an
        uninterrupted single-device reference run."""
        cfg, params = _setup(channel=channel, **overrides)
        eng = _two_shard(cfg, PoolConfig(max_slots=1, max_new=32,
                                         max_prompt=16))
        sched = SLAScheduler(backoff_s=0.0, max_retries=10_000)
        eng.attach_scheduler(sched)
        base = jax.random.PRNGKey(11)
        # A: best-effort (inf deadline -> preferred preemption victim).
        ra = eng.submit(_prompt(0, 7, cfg.vocab_size), 8,
                        key=jax.random.fold_in(base, 0))
        eng.step(params)
        assert eng.placements[ra.rid] == [0]
        # B: same priority, finite deadline -> kept; fills shard 1, and
        # retires first so shard 1 is where A's resume lands.
        rb = eng.submit(_prompt(1, 5, cfg.vocab_size), 4,
                        key=jax.random.fold_in(base, 1),
                        sla=SLA(deadline_s=60.0))
        eng.step(params)
        assert eng.placements[rb.rid] == [1]
        # C: higher priority, long-running -> preempts A off shard 0 and
        # keeps shard 0 busy until well after A resumes.
        rc = eng.submit(_prompt(2, 9, cfg.vocab_size), 24,
                        key=jax.random.fold_in(base, 2),
                        sla=SLA(priority=5))
        done = eng.run(params)
        assert len(done) == 3
        assert ra.n_preempts == 1
        assert eng.placements[ra.rid] == [0, 1], eng.placements
        assert eng.placements[rc.rid] == [0]
        assert sched.stats["preemptions"] == 1
        assert sched.stats["resumes"] == 1
        _check_reference(cfg, params, [ra, rb, rc], base)


# ---------------------------------------------------------------------------
# Backpressure: all shards exhausted -> aggregated PoolExhausted
# ---------------------------------------------------------------------------


class TestAllShardsExhausted:
    def test_typed_fields_aggregate_across_shards(self):
        cfg, params = _setup()
        pool = PoolConfig(max_slots=2, max_new=8, max_prompt=16,
                          paged=True, block_size=4, exhaust_wait_steps=3)
        eng = _two_shard(cfg, pool)
        # A chaos squeeze holds EVERY allocatable block on EVERY shard.
        chaos = EngineChaos(
            eng, ChaosSchedule([block_pool_squeeze(0.0, 100.0, 1.0)])
        )
        chaos.apply(now=1.0)
        per_shard = pool.total_blocks - 1
        assert chaos.held_blocks == 2 * per_shard
        req = eng.submit(_prompt(0, 8, cfg.vocab_size), 4,
                         key=jax.random.PRNGKey(5))
        with pytest.raises(PoolExhausted) as exc:
            for _ in range(pool.exhaust_wait_steps + 2):
                eng.step(params)
        e = exc.value
        assert e.queued == 1
        assert e.free_slots == 4          # sum across shards: 2 x 2 slots
        assert e.free_blocks == 0         # sum across shards, all held
        assert e.need_blocks == eng.blocks_needed(8, 4) > 0
        # Release the squeeze: the same queue drains normally.
        chaos.release_all()
        assert chaos.held_blocks == 0
        done = eng.run(params)
        assert len(done) == 1 and done[0] is req
        ref, _ = generate_reference(
            params, cfg, req.prompt[None], 4, key=jax.random.PRNGKey(5)
        )
        np.testing.assert_array_equal(req.tokens, np.asarray(ref)[0])


# ---------------------------------------------------------------------------
# Steady state: zero builds over a mixed-shard workload after warm()
# ---------------------------------------------------------------------------


class TestRouterNoRecompile:
    def test_steady_state_mixed_shard_workload(self):
        cfg, params = _setup()
        eng = _two_shard(cfg, PoolConfig(max_slots=2, max_new=8,
                                         max_prompt=16))
        lengths = (5, 9, 12, 7, 16, 6)
        eng.warm(params, lengths)
        for sh in eng.shards:
            assert sh.compiles == sh.num_buckets + 1
        # Precompute prompts/keys: fold_in itself compiles a tiny program
        # on first use, which is warm-up work, not serving work.
        base = jax.random.PRNGKey(13)
        prompts = [_prompt(i, n, cfg.vocab_size) for i, n in
                   enumerate(lengths)]
        keys = [jax.random.fold_in(base, i) for i in range(len(lengths))]
        jax.block_until_ready(keys)
        with no_recompile(engines=(eng, *eng.shards)):
            reqs = [
                eng.submit(p, 6, key=k) for p, k in zip(prompts, keys)
            ]
            done = eng.run(params)
        assert len(done) == len(lengths)
        assert all(c > 0 for c in eng.placement_counts)
        for sh in eng.shards:
            assert sh.compiles == sh.num_buckets + 1
        _check_reference(cfg, params, reqs, base)


# ---------------------------------------------------------------------------
# The real thing: forced 4-device host mesh (subprocess)
# ---------------------------------------------------------------------------


class TestForcedMultiDevice:
    def test_router_on_four_devices(self):
        code = """
import dataclasses
import numpy as np, jax, jax.numpy as jnp
import pytest
from repro.configs import ARCHITECTURES
from repro.launch.mesh import make_host_mesh, host_devices
from repro.launch.serve import generate_reference
from repro.models import lm
from repro.serve import PoolConfig, ShardedEngine
from repro.sharding.rules import pool_shard_devices

assert len(jax.devices()) == 4, jax.devices()

# Mesh builders under the forced backend.
mesh = make_host_mesh()
assert mesh.shape == {"data": 4, "model": 1}
devs = pool_shard_devices(mesh)
assert len(devs) == 4 and len({d.id for d in devs}) == 4
try:
    pool_shard_devices(make_host_mesh(4))
except ValueError as e:
    assert "slot" in str(e)
else:
    raise AssertionError("model-axis>1 mesh must be rejected")
import os
os.environ["REPRO_HOST_DEVICES"] = "2"
assert len(host_devices()) == 2
del os.environ["REPRO_HOST_DEVICES"]

cfg = ARCHITECTURES["qwen1.5-0.5b"].reduced(attn_impl="flash_decode")
cfg = cfg.with_updates(
    link=dataclasses.replace(cfg.link, loss_rate=0.3, channel="ge")
)
params = lm.init_lm(jax.random.PRNGKey(0), cfg)
eng = ShardedEngine(
    cfg, PoolConfig(max_slots=1, max_new=8, max_prompt=16), mesh=mesh
)
assert eng.num_shards == 4
base = jax.random.PRNGKey(21)
lengths = (5, 9, 12, 7, 16, 6, 11, 8)
reqs = [
    eng.submit(
        np.asarray(jax.random.randint(
            jax.random.fold_in(jax.random.PRNGKey(7), i), (n,), 0,
            cfg.vocab_size, jnp.int32,
        )), 6, key=jax.random.fold_in(base, i))
    for i, n in enumerate(lengths)
]
done = eng.run(params)
assert len(done) == len(lengths)
assert all(c > 0 for c in eng.placement_counts), eng.placement_counts
for sh in eng.shards:
    assert sh.compiles == sh.num_buckets + 1, (sh.compiles, sh.num_buckets)
for i, req in enumerate(reqs):
    ref, _ = generate_reference(
        params, cfg, req.prompt[None], req.max_tokens,
        key=jax.random.fold_in(base, i),
    )
    np.testing.assert_array_equal(req.tokens, np.asarray(ref)[0])
print("OK_4DEV_ROUTER")
"""
        env = dict(os.environ)
        env.pop(HOST_DEVICES_ENV, None)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env["JAX_PLATFORMS"] = "cpu"
        src = str(REPO_ROOT / "src")
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
        )
        r = subprocess.run(
            [sys.executable, "-c", code], env=env,
            capture_output=True, text=True, timeout=540,
        )
        assert r.returncode == 0 and "OK_4DEV_ROUTER" in r.stdout, (
            r.stdout[-2000:], r.stderr[-4000:]
        )
