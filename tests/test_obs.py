"""Observability (repro.obs): registry, streaming histograms, exporters,
trace-time link taps, and the engine's on-device counters.

The two load-bearing guarantees:

* **Obs never changes the programs.**  The slot-pool engine carries its
  ``DeviceCounters`` pytree unconditionally, so enabling the registry adds
  ZERO XLA compiles, keeps ``compiles == num_buckets + 1``, and greedy
  outputs stay token-identical to ``generate_reference`` (iid + GE).
* **The device counters are exact.**  The realized link statistics
  harvested from the engine equal an eager oracle that replays the
  per-request key chain through ``lm.make_link_fn`` (the identical
  ``emulate_link`` closure) on zero messages of the engine's shapes —
  mask draws depend only on (key, shape), so the oracle reproduces every
  engine draw including the padded bucket positions.
"""

import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs import ARCHITECTURES
from repro.launch.serve import generate_reference
from repro.analysis.guards import no_recompile
from repro.models import cache as cache_lib, lm
from repro.obs import device as obs_device, exporters
from repro.obs.registry import Registry
from repro.obs.stats import StreamingHistogram, latency_summary, percentile
from repro.serve import ContinuousEngine, PoolConfig


def _setup(channel="iid", loss_rate=0.3):
    cfg = ARCHITECTURES["qwen1.5-0.5b"].reduced()
    cfg = cfg.with_updates(
        link=dataclasses.replace(cfg.link, loss_rate=loss_rate, channel=channel)
    )
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompt(i, length, vocab):
    return np.asarray(
        jax.random.randint(
            jax.random.fold_in(jax.random.PRNGKey(7), i), (length,), 0, vocab,
            jnp.int32,
        )
    )


@pytest.fixture
def global_registry_enabled():
    """Enable the process-global registry for one test, restore after."""
    reg = obs.registry()
    was = reg.enabled
    reg.reset()
    reg.enable()
    yield reg
    reg.reset()
    reg.enabled = was


# ---------------------------------------------------------------------------
# obs.stats: exact percentiles + the streaming histogram
# ---------------------------------------------------------------------------

class TestStats:
    def test_percentile_matches_numpy(self):
        rng = np.random.RandomState(0)
        xs = list(rng.lognormal(-3, 1.5, size=257))
        for q in (0, 10, 50, 90, 99, 100):
            assert percentile(xs, q) == float(np.percentile(xs, q))

    def test_latency_summary_contract(self):
        xs = [0.5, 0.1, 0.9, 0.3]
        s = latency_summary(xs)
        assert set(s) == {"p50_s", "p90_s", "p99_s", "mean_s"}
        assert s["p50_s"] == float(np.percentile(xs, 50))
        assert s["p99_s"] == float(np.percentile(xs, 99))
        assert s["mean_s"] == pytest.approx(np.mean(xs))
        assert latency_summary([]) == {
            "p50_s": 0.0, "p90_s": 0.0, "p99_s": 0.0, "mean_s": 0.0
        }

    def test_streaming_histogram_quantiles(self):
        """p50/p90/p99 of a lognormal stream within the bucket-ratio error
        bound; count/sum/min/max exact."""
        rng = np.random.RandomState(3)
        xs = rng.lognormal(-4, 1.0, size=5000)    # latency-ish seconds
        h = StreamingHistogram()
        for v in xs:
            h.observe(float(v))
        assert h.count == len(xs)
        assert h.total == pytest.approx(xs.sum())
        assert h.min == xs.min() and h.max == xs.max()
        for q in (50, 90, 99):
            want = np.percentile(xs, q)
            assert h.quantile(q) == pytest.approx(want, rel=0.15), q

    def test_streaming_histogram_clamps_to_observed_extremes(self):
        h = StreamingHistogram()
        h.observe(0.25)
        assert h.quantile(0) == 0.25
        assert h.quantile(100) == 0.25
        assert h.summary()["count"] == 1.0

    def test_streaming_histogram_empty(self):
        h = StreamingHistogram()
        assert h.quantile(50) == 0.0
        assert h.summary()["count"] == 0.0 and h.summary()["min"] == 0.0


# ---------------------------------------------------------------------------
# Registry: disabled no-op contract, enabled metrics + span nesting
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_disabled_is_null(self):
        reg = Registry(enabled=False)
        reg.counter("c").inc(5)
        reg.gauge("g").set(2.0)
        reg.histogram("h").observe(1.0)
        with reg.span("s", x=1):
            reg.event("e")
        assert reg.record_span("r", 0.0, 1.0) is None
        snap = reg.snapshot()
        assert snap["counters"] == {} and snap["gauges"] == {}
        assert snap["histograms"] == {} and reg.events == []
        # The null singletons are shared (no per-call allocation).
        assert reg.counter("a") is reg.counter("b")
        assert reg.span("x") is reg.span("y")

    def test_enabled_metrics(self):
        reg = Registry(enabled=True)
        reg.counter("c").inc()
        reg.counter("c").inc(2.5)
        reg.gauge("g").set(7)
        reg.histogram("h").observe(0.5)
        snap = reg.snapshot()
        assert snap["counters"]["c"] == 3.5
        assert snap["gauges"]["g"] == 7.0
        assert snap["histograms"]["h"]["count"] == 1.0

    def test_span_nesting_sets_parent(self):
        reg = Registry(enabled=True)
        with reg.span("outer"):
            with reg.span("inner", depth=1):
                pass
        inner, outer = reg.events       # inner closes (appends) first
        assert inner["name"] == "inner" and outer["name"] == "outer"
        assert inner["parent"] == outer["id"]
        assert "parent" not in outer
        assert inner["t"] >= outer["t"]
        assert inner["dur"] <= outer["dur"] + 1e-9
        assert inner["attrs"] == {"depth": 1}

    def test_record_span_parents_and_ordering(self):
        reg = Registry(enabled=True)
        pid = reg.record_span("p", 1.0, 3.0, rid=9)
        cid = reg.record_span("c", 1.5, 2.0, parent=pid, rid=9)
        assert isinstance(pid, int) and isinstance(cid, int) and cid != pid
        assert reg.events[1]["parent"] == pid
        # Negative durations clamp (out-of-order stamps must not corrupt
        # the trace).
        reg.record_span("z", 5.0, 4.0)
        assert reg.events[2]["dur"] == 0.0

    def test_event_cap_drops_not_grows(self):
        reg = Registry(enabled=True, max_events=3)
        for i in range(5):
            reg.event("e", i=i)
        assert len(reg.events) == 3 and reg.events_dropped == 2

    def test_reset_clears(self):
        reg = Registry(enabled=True)
        reg.counter("c").inc()
        reg.event("e")
        reg.reset()
        assert reg.enabled and reg.events == []
        assert reg.snapshot()["counters"] == {}


# ---------------------------------------------------------------------------
# Exporters: JSONL / Prometheus / chrome trace / span-chain checker
# ---------------------------------------------------------------------------

def _chain_registry():
    """A registry holding one complete request chain and one incomplete."""
    reg = Registry(enabled=True)
    reg.counter("serve.tokens_generated").inc(12)
    reg.gauge("serve.device.realized_drop_rate").set(0.25)
    reg.histogram("serve.ttft_s").observe(0.01)
    p = reg.record_span("request", 1.0, 2.0, rid=0)
    for name, (a, b) in zip(
        exporters.REQUEST_PHASES, [(1.0, 1.2), (1.2, 1.4), (1.4, 1.9), (1.9, 2.0)]
    ):
        reg.record_span(name, a, b, parent=p, rid=0)
    q = reg.record_span("request", 2.0, 3.0, rid=1)
    reg.record_span("request/queue", 2.0, 2.1, parent=q, rid=1)  # incomplete
    return reg


class TestExporters:
    def test_jsonl_roundtrip(self, tmp_path):
        reg = _chain_registry()
        path = tmp_path / "events.jsonl"
        exporters.write_jsonl(reg, str(path))
        lines = [json.loads(l) for l in path.read_text().splitlines()]
        assert lines[0]["kind"] == "snapshot"
        assert lines[0]["counters"]["serve.tokens_generated"] == 12.0
        spans = [l for l in lines[1:] if l["kind"] == "span"]
        assert len(spans) == len(reg.events)
        assert {s["name"] for s in spans} >= {"request", *exporters.REQUEST_PHASES}

    def test_prometheus_text(self):
        text = exporters.prometheus_text(_chain_registry())
        assert "# TYPE serve_tokens_generated counter" in text
        assert "serve_tokens_generated 12.0" in text
        assert "serve_device_realized_drop_rate 0.25" in text
        assert '# TYPE serve_ttft_s summary' in text
        assert 'serve_ttft_s{quantile="0.50"}' in text
        assert "serve_ttft_s_count 1" in text

    def test_chrome_trace_structure(self, tmp_path):
        reg = _chain_registry()
        reg.event("marker")
        path = tmp_path / "trace.json"
        exporters.write_chrome_trace(reg, str(path))
        tr = json.loads(path.read_text())
        evs = tr["traceEvents"]
        assert len(evs) == len(reg.events)
        complete = [e for e in evs if e["ph"] == "X"]
        assert complete and all("dur" in e and e["dur"] >= 0 for e in complete)
        assert any(e["ph"] == "i" for e in evs)
        req = next(e for e in complete if e["name"] == "request")
        assert req["dur"] == pytest.approx(1.0 * 1e6)   # microseconds

    def test_request_chain_rids(self):
        rids = exporters.request_chain_rids(_chain_registry())
        assert rids == {0}         # rid 1 is missing three phases

    def test_jax_profile_noop_without_dir(self):
        with exporters.jax_profile(None):
            pass


# ---------------------------------------------------------------------------
# Trace-time link taps
# ---------------------------------------------------------------------------

class TestLinkTaps:
    def test_apply_channel_mask_stats(self):
        """Tapped elems/dropped equal an independent recount from the
        masked output (kept positions are nonzero under compensation)."""
        from repro.core.link import apply_channel

        key = jax.random.PRNGKey(5)
        x = jnp.ones((4, 25), jnp.float32)
        with obs_device.tap_link_stats() as tap:
            y = apply_channel(key, x, 0.4)
            tot = {k: float(v) for k, v in tap.totals().items()}
        dropped = float(jnp.sum(np.asarray(y) == 0.0))
        assert tot["elems"] == x.size
        assert tot["dropped"] == dropped
        assert tot["fec_recovered"] == 0.0

    def test_untapped_is_silent(self):
        from repro.core.link import apply_channel

        assert not obs_device.tapping()
        apply_channel(jax.random.PRNGKey(0), jnp.ones((2, 2)), 0.5)
        assert not obs_device.tapping()

    def test_zero_loss_records_full_keep(self):
        from repro.core.comtune import LinkSpec, channel_link

        spec = LinkSpec(loss_rate=0.0)
        x = jnp.ones((1, 1, 50), jnp.float32)
        with obs_device.tap_link_stats() as tap:
            channel_link(jax.random.PRNGKey(0), x, spec)
            tot = {k: float(v) for k, v in tap.totals().items()}
        assert tot["elems"] == 50.0 and tot["dropped"] == 0.0

    def test_streamed_link_sums_per_position_rounds(self):
        """The streamed (vmapped) prefill link's totals equal the sum of
        the per-position draws taken individually."""
        from repro.core.comtune import LinkSpec, channel_link, streamed_channel_link

        spec = LinkSpec(loss_rate=0.35)
        key = jax.random.PRNGKey(9)
        msg = jnp.ones((1, 6, 40), jnp.float32)
        with obs_device.tap_link_stats() as tap:
            out = streamed_channel_link(key, msg, spec)
            tot = {k: float(v) for k, v in tap.totals().items()}
        assert tot["elems"] == msg.size
        # Independent recount from the realized zeros.
        assert tot["dropped"] == float(jnp.sum(np.asarray(out) == 0.0))

    def test_fec_recovery_count_hand_built_blocks(self):
        """k=4, m=2 RS over two blocks with a hand-built raw packet draw:
        block 1 loses 1 data packet but keeps 4-of-6 (recoverable -> +1),
        block 2 keeps 2-of-6 (unrecoverable -> +0)."""
        from repro.net.fec import FECSpec, fec_element_keep_jnp

        raw = jnp.asarray(
            [1, 1, 1, 0, 1, 0,      # block 1: data 3/4, total 4 >= k
             0, 0, 1, 1, 0, 0],     # block 2: data 2/4, total 2 < k
            jnp.float32,
        )

        class FixedChannel:
            def packet_keep_jnp(self, key, n):
                assert n == raw.size
                return raw

        spec = FECSpec(k=4, m=2)
        with obs_device.tap_link_stats() as tap:
            keep = fec_element_keep_jnp(
                jax.random.PRNGKey(0), FixedChannel(), 40, 5, spec
            )
            recovered = float(tap.totals()["fec_recovered"])
        assert recovered == 1.0
        # Block 1 fully recovered, block 2 delivers only its survivors.
        np.testing.assert_array_equal(
            np.asarray(keep).reshape(8, 5)[:, 0],
            [1, 1, 1, 1, 0, 0, 1, 1],
        )

    def test_unbalanced_stack_is_rejected(self):
        with pytest.raises(AssertionError):
            with obs_device.tap_link_stats():
                obs_device._STACK.append(obs_device.LinkTap())
        obs_device._STACK.clear()


# ---------------------------------------------------------------------------
# decode_read_bytes: traced twin == int analytic
# ---------------------------------------------------------------------------

class TestDecodeReadBytesJnp:
    def test_matches_int_analytic(self):
        cfg, _ = _setup()
        max_seq = 64
        valids = [1, 3, 17, 33, 64]
        for masked in (True, False):
            want = [
                cache_lib.decode_read_bytes(cfg, max_seq, v, masked=masked)
                for v in valids
            ]
            got = cache_lib.decode_read_bytes_jnp(
                cfg, max_seq, jnp.asarray(valids), masked=masked
            )
            np.testing.assert_array_equal(np.asarray(got), want)
            # Scalar form agrees too.
            for v, w in zip(valids, want):
                assert float(
                    cache_lib.decode_read_bytes_jnp(cfg, max_seq, v, masked=masked)
                ) == w


# ---------------------------------------------------------------------------
# Engine device counters vs the eager key-chain oracle
# ---------------------------------------------------------------------------

def _oracle_link_totals(cfg, params, jobs):
    """Replay each request's RNG chain through the exact serve-link closure
    (``lm.make_link_fn``) on zeros of the engine's message shapes: one
    streamed round over the PADDED bucket, then one (1, 1, d) round per
    generated token.  Mask draws depend only on (key, shape)."""
    from repro.models.common import dtype_of

    d, dt = cfg.d_model, dtype_of(cfg.dtype)
    tot = {"elems": 0.0, "dropped": 0.0, "fec_recovered": 0.0}
    for bucket, n_tokens, rkey in jobs:
        k, sub = jax.random.split(rkey)
        with obs_device.tap_link_stats() as tap:
            lm.make_link_fn(cfg, params["link"], sub, "serve")(
                jnp.zeros((1, bucket, d), dt)
            )
            for _ in range(n_tokens):
                k, sub = jax.random.split(k)
                lm.make_link_fn(cfg, params["link"], sub, "serve")(
                    jnp.zeros((1, 1, d), dt)
                )
            t = tap.totals()
        for name in tot:
            tot[name] += float(t[name])
    return tot


class TestDeviceCounterOracle:
    @pytest.mark.parametrize("channel", ["iid", "ge"])
    def test_link_counters_match_oracle(self, channel):
        cfg, params = _setup(channel=channel)
        eng = ContinuousEngine(
            cfg, PoolConfig(max_slots=2, max_new=4, max_prompt=8, min_bucket=8)
        )
        key = jax.random.PRNGKey(21)
        spec = [(5, 3), (7, 2), (3, 4)]          # (prompt_len, tokens)
        for i, (L, T) in enumerate(spec):
            eng.submit(_prompt(i, L, cfg.vocab_size), T,
                       key=jax.random.fold_in(key, i))
        eng.run(params)
        got = eng.device_counters()
        jobs = [
            (eng.bucket_for(L), T, jax.random.fold_in(key, i))
            for i, (L, T) in enumerate(spec)
        ]
        want = _oracle_link_totals(cfg, params, jobs)
        np.testing.assert_allclose(got["link_elems"], want["elems"], rtol=1e-6)
        np.testing.assert_allclose(
            got["link_dropped"], want["dropped"], rtol=1e-6, atol=0.5
        )
        np.testing.assert_allclose(
            got["fec_recovered_packets"], want["fec_recovered"],
            rtol=1e-6, atol=0.5,
        )
        assert got["link_dropped"] > 0          # loss_rate 0.3 must drop
        assert 0.0 < got["realized_drop_rate"] < 1.0

    def test_valid_tokens_and_read_bytes_exact(self):
        cfg, params = _setup(loss_rate=0.0)
        pool = PoolConfig(max_slots=2, max_new=5, max_prompt=8, min_bucket=8)
        eng = ContinuousEngine(cfg, pool)
        key = jax.random.PRNGKey(4)
        spec = [(5, 3), (7, 5), (2, 1)]
        for i, (L, T) in enumerate(spec):
            eng.submit(_prompt(i, L, cfg.vocab_size), T,
                       key=jax.random.fold_in(key, i))
        eng.run(params)
        got = eng.device_counters()
        assert got["decode_steps"] == eng.steps
        # Live decode step t of a request sees valid = L + t + 1.
        want_valid = sum(
            sum(L + t + 1 for t in range(T)) for L, T in spec
        )
        assert got["valid_tokens"] == want_valid
        masked = cfg.attn_impl != "naive"
        want_bytes = sum(
            sum(
                cache_lib.decode_read_bytes(cfg, pool.max_seq, L + t + 1,
                                            masked=masked)
                for t in range(T)
            )
            for L, T in spec
        )
        assert got["decode_read_bytes"] == want_bytes

    def test_counters_before_first_run_are_zero(self):
        cfg, _ = _setup()
        eng = ContinuousEngine(cfg, PoolConfig(max_slots=2))
        got = eng.device_counters()
        assert got["realized_drop_rate"] == 0.0
        assert all(v == 0.0 for v in got.values())


# ---------------------------------------------------------------------------
# Obs on/off never changes the compiled programs or the tokens
# ---------------------------------------------------------------------------

class TestObsProgramInvariance:
    @pytest.mark.parametrize("channel", ["iid", "ge"])
    def test_enabled_registry_token_identity_and_compiles(
        self, channel, global_registry_enabled
    ):
        """With the registry ENABLED: compiles == num_buckets + 1 and the
        greedy outputs still equal the per-request reference."""
        cfg, params = _setup(channel=channel)
        eng = ContinuousEngine(
            cfg, PoolConfig(max_slots=2, max_new=4, max_prompt=16, min_bucket=8)
        )
        key = jax.random.PRNGKey(13)
        lengths = [5, 12, 7]
        reqs = [
            eng.submit(_prompt(i, L, cfg.vocab_size), 3,
                       key=jax.random.fold_in(key, i))
            for i, L in enumerate(lengths)
        ]
        eng.run(params)
        assert eng.compiles == eng.num_buckets + 1
        for i, (L, req) in enumerate(zip(lengths, reqs)):
            ref, _ = generate_reference(
                params, cfg, jnp.asarray(_prompt(i, L, cfg.vocab_size))[None],
                3, key=jax.random.fold_in(key, i),
            )
            np.testing.assert_array_equal(np.asarray(ref)[0], req.tokens)

    def test_toggling_obs_adds_zero_compiles(self):
        """Enable the registry mid-run: more traffic on warm buckets must
        not build a single new program (obs state is carried either way)."""
        reg = obs.registry()
        assert not reg.enabled
        cfg, params = _setup()
        eng = ContinuousEngine(
            cfg, PoolConfig(max_slots=2, max_new=3, max_prompt=8, min_bucket=8)
        )
        key = jax.random.PRNGKey(2)
        eng.submit(_prompt(0, 5, cfg.vocab_size), 2, key=key)
        eng.run(params)
        warm = eng.compiles
        # prompts/keys precomputed: _prompt's randint traces a throwaway
        # program per fresh length, which the compile guard must not see
        traffic = [
            (_prompt(1 + i, 4 + i, cfg.vocab_size),
             jax.random.fold_in(key, i))
            for i in range(3)
        ]
        reg.enable()
        try:
            with no_recompile(engines=(eng,)):
                for prompt, k in traffic:
                    eng.submit(prompt, 2, key=k)
                eng.run(params)
            assert eng.compiles == warm
            assert eng.traces == warm
        finally:
            reg.disable()
            reg.reset()


# ---------------------------------------------------------------------------
# Request lifecycle spans + timing granularity
# ---------------------------------------------------------------------------

class TestRequestLifecycle:
    def test_span_chain_and_timestamp_ordering(self, global_registry_enabled):
        reg = global_registry_enabled
        cfg, params = _setup()
        eng = ContinuousEngine(
            cfg, PoolConfig(max_slots=2, max_new=4, max_prompt=8, min_bucket=8)
        )
        key = jax.random.PRNGKey(6)
        reqs = [
            eng.submit(_prompt(i, 4 + i, cfg.vocab_size), 3,
                       key=jax.random.fold_in(key, i))
            for i in range(3)
        ]
        eng.run(params)
        for r in reqs:
            assert r.t_submit <= r.t_admit <= r.t_first_token
            assert r.t_first_token <= r.t_done <= r.t_retire
            assert r.ttft_s > 0 and r.tpot_s >= 0 and r.e2e_s >= r.ttft_s
        # Every request closed a complete submit->retire chain.
        assert exporters.request_chain_rids(reg) == {r.rid for r in reqs}
        snap = reg.snapshot()
        assert snap["counters"]["serve.requests_submitted"] == 3.0
        assert snap["counters"]["serve.requests_retired"] == 3.0
        assert snap["counters"]["serve.tokens_generated"] == 9.0
        assert snap["histograms"]["serve.ttft_s"]["count"] == 3.0
        # run() published the device counters as gauges.
        assert "serve.device.realized_drop_rate" in snap["gauges"]

    def test_request_stats_summary_keys(self):
        cfg, params = _setup()
        eng = ContinuousEngine(
            cfg, PoolConfig(max_slots=2, max_new=3, max_prompt=8, min_bucket=8)
        )
        eng.submit(_prompt(0, 5, cfg.vocab_size), 2)
        eng.run(params)
        s = eng.stats()
        for k in ("ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "e2e_mean_s",
                  "requests"):
            assert k in s, k
        assert s["requests"] == 1.0 and s["e2e_mean_s"] > 0


# ---------------------------------------------------------------------------
# Disabled-registry overhead
# ---------------------------------------------------------------------------

class TestDisabledOverhead:
    def test_null_path_cost_is_negligible(self):
        """~32 registry touches per decode step must cost well under 2% of
        even a fast (5 ms) step: bound the per-op null-path cost."""
        reg = Registry(enabled=False)
        n = 20_000
        t0 = time.perf_counter()
        for _ in range(n):
            reg.counter("c").inc()
            reg.gauge("g").set(1.0)
            reg.histogram("h").observe(1.0)
            with reg.span("s"):
                pass
        per_op = (time.perf_counter() - t0) / (4 * n)
        assert per_op < 2e-6, f"null-path op cost {per_op*1e9:.0f} ns"
        assert 32 * per_op < 0.02 * 0.005      # 32 ops vs 2% of a 5 ms step


# ---------------------------------------------------------------------------
# Train metrics carry the link stats
# ---------------------------------------------------------------------------

class TestTrainLinkMetrics:
    def test_train_step_metrics_have_link_stats(self):
        from repro.launch.steps import make_train_step
        from repro.optim import AdamConfig, init_adam

        cfg, params = _setup(loss_rate=0.0)
        adam_cfg = AdamConfig(lr=1e-3)
        opt = init_adam(params, adam_cfg)
        tokens = jnp.zeros((2, 8), jnp.int32)
        for mode, expect_draws in (("train", True), ("off", False)):
            step = jax.jit(make_train_step(cfg, adam_cfg, link_mode=mode))  # noqa: RPA001 — one compile per link_mode under test
            _, _, metrics = step(params, opt, {"tokens": tokens},
                                 jax.random.PRNGKey(0))
            for k in ("link_elems", "link_dropped", "fec_recovered_packets"):
                assert k in metrics, (mode, k)
            elems = float(metrics["link_elems"])
            assert (elems > 0) == expect_draws, mode


# ---------------------------------------------------------------------------
# Simulator: shared stats + registry export
# ---------------------------------------------------------------------------

class TestSimulatorObs:
    def test_sim_registry_export(self, global_registry_enabled):
        from repro.net import SimConfig, run_sim

        reg = global_registry_enabled
        rep = run_sim(SimConfig(n_clients=3, duration_s=1.5, seed=2))
        assert rep.served > 0
        snap = reg.snapshot()
        assert snap["counters"]["sim.requests_arrived"] == rep.arrived
        assert snap["counters"]["sim.requests_served"] == rep.served
        assert snap["histograms"]["sim.latency_s"]["count"] == rep.served
        names = [e["name"] for e in reg.events]
        assert names.count("sim.request") == rep.served
        assert names.count("sim.uplink") == rep.served
        assert "sim.run" in names
        # Uplink spans sit inside their request span on the sim clock.
        by_id = {e["id"]: e for e in reg.events if e["kind"] == "span"}
        for e in reg.events:
            if e["name"] == "sim.uplink":
                parent = by_id[e["parent"]]
                assert parent["name"] == "sim.request"
                assert e["t"] >= parent["t"] - 1e-9
                assert e["t"] + e["dur"] <= parent["t"] + parent["dur"] + 1e-9

    def test_uplink_start_is_stamped(self):
        from repro.net import SimConfig, run_sim

        calls = []

        def fake_engine(batch):
            calls.extend(batch)
            return 0.01

        run_sim(
            SimConfig(n_clients=1, n_packets=4, duration_s=1.0,
                      min_delivered_fraction=0.0),
            arrivals=[(0.0, 0), (0.0, 0)],
            engine=fake_engine,
        )
        # Second request queued behind the busy radio: its uplink starts
        # when the first one's finishes, not at arrival.
        a, b = sorted(calls, key=lambda r: r.rid)
        assert a.t_uplink_start == pytest.approx(a.t_arrival)
        assert b.t_uplink_start == pytest.approx(a.t_uplink_done)

    def test_sim_disabled_stays_silent(self):
        from repro.net import SimConfig, run_sim

        reg = obs.registry()
        assert not reg.enabled
        before = len(reg.events)
        rep = run_sim(SimConfig(n_clients=2, duration_s=1.0, seed=0))
        assert rep.latency_p50_s >= 0.0
        assert len(reg.events) == before


# ---------------------------------------------------------------------------
# Router observability: per-shard occupancy gauges vs the host oracle
# ---------------------------------------------------------------------------

class TestRouterGauges:
    """The sharded router publishes per-shard occupancy at the existing
    host sync points (admission / preemption / completion) plus a final
    refresh in run().  The gauges must equal the host-side oracle — the
    same public probes (`free_slot_count` / `free_block_count()`) the
    placement policy itself reads."""

    def test_shard_gauges_and_placement_counters(self, global_registry_enabled):
        from repro.serve import ShardedEngine

        reg = global_registry_enabled
        cfg, params = _setup()
        dev = jax.devices()[0]
        eng = ShardedEngine(
            cfg, PoolConfig(max_slots=2, max_new=8, max_prompt=16),
            devices=[dev, dev],
        )
        base = jax.random.PRNGKey(5)
        reqs = [
            eng.submit(_prompt(i, n, cfg.vocab_size), 4,
                       key=jax.random.fold_in(base, i))
            for i, n in enumerate((5, 9, 12))
        ]
        eng.step(params)
        # Mid-flight: occupancy gauges reflect the state after the last
        # admission, which decode does not change until a completion.
        snap = reg.snapshot()
        assert eng.active == 3
        for i, sh in enumerate(eng.shards):
            assert snap["gauges"][f"serve.shard_free_slots.{i}"] == float(
                sh.free_slot_count
            )
            assert snap["gauges"][f"serve.shard_free_blocks.{i}"] == float(
                sh.free_block_count()
            )
        done = eng.run(params)
        assert len(done) == len(reqs)
        snap = reg.snapshot()
        # Terminal refresh: pool fully idle again.
        assert snap["gauges"]["router.queue_depth"] == 0.0
        for i in range(eng.num_shards):
            assert snap["gauges"][f"serve.shard_free_slots.{i}"] == float(
                eng.pool.max_slots
            )
        # Placement counters == the router's own placement ledger, and
        # every admission was counted exactly once (no preemptions here).
        assert snap["counters"]["router.placements"] == float(len(reqs))
        for i in range(eng.num_shards):
            assert snap["counters"].get(
                f"router.placements.shard{i}", 0.0
            ) == float(eng.placement_counts[i])
        assert snap["counters"]["serve.requests_submitted"] == float(len(reqs))
        # run() published the shard-summed device counters as gauges,
        # with the drop rate re-derived from the summed totals.
        host = eng.device_counters()
        for k, v in host.items():
            assert snap["gauges"][f"serve.device.{k}"] == pytest.approx(v)
        assert snap["gauges"]["serve.device.link_elems"] == pytest.approx(
            sum(sh.device_counters()["link_elems"] for sh in eng.shards)
        )
