"""SLA scheduler over the continuous engine (repro.serve.scheduler).

Two layers of coverage:

* **Unit** — the scheduling policy against a slot/block ledger double
  with the engine's public host API: EDF-within-priority order, deadline
  / EMA / feasibility-oracle expiry, bounded retry with backoff into
  terminal rejection, strictly-lower-priority all-or-nothing preemption.
* **Integration (acceptance)** — preempt/resume on the REAL paged engine
  is greedy token-identical to ``generate_reference`` for every request
  (victims included) under iid + GE links, int8 pools, and rotating
  windows wrapping across block boundaries; steady state with scheduler
  + chaos squeeze performs ZERO new XLA builds under the ``no_recompile``
  guard with ``compiles == num_buckets + 1``; and the unscheduled engine
  raises typed ``PoolExhausted`` backpressure after its wait budget when
  a chaos squeeze pins the pool.
"""

import dataclasses
import math
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.guards import no_recompile
from repro.configs import ARCHITECTURES
from repro.launch.serve import generate_reference
from repro.models import lm
from repro.net import ChaosSchedule, block_pool_squeeze
from repro.net.chaos import EngineChaos
from repro.serve import (
    SLA,
    ContinuousEngine,
    PoolConfig,
    PoolExhausted,
    SLAScheduler,
    VirtualClock,
    protocol_feasibility,
)

# ---------------------------------------------------------------------------
# Unit layer: the policy against a ledger double of the engine host API
# ---------------------------------------------------------------------------


class _FakeEngine:
    """Slot/block ledger exposing exactly the public host surface the
    scheduler is allowed to touch (RPA007): try_admit / preempt_slot /
    running_slots / free_slot_count / free_block_count / blocks_needed /
    blocks_held."""

    def __init__(self, slots=1, blocks=0, paged=False, block_size=4):
        self.pool = types.SimpleNamespace(
            paged=paged, total_blocks=blocks + 1
        )
        self._slots = slots
        self._block_size = block_size
        self._free_blocks = blocks
        self._running = {}           # slot -> req
        self._held = {}              # slot -> block count
        self.admit_log = []

    @property
    def free_slot_count(self):
        return self._slots - len(self._running)

    def free_block_count(self):
        return self._free_blocks

    def running_slots(self):
        return sorted(self._running.items())

    def blocks_needed(self, prompt_len, max_tokens):
        return -(-(prompt_len + max_tokens) // self._block_size)

    def blocks_held(self, slot):
        return self._held.get(slot, 0)

    def try_admit(self, params, req):
        if self.free_slot_count <= 0:
            return False
        need = (self.blocks_needed(req.prompt.size, req.max_tokens)
                if self.pool.paged else 0)
        if self.pool.paged and need > self._free_blocks:
            return False
        slot = next(s for s in range(self._slots)
                    if s not in self._running)
        self._running[slot] = req
        if self.pool.paged:
            self._free_blocks -= need
            self._held[slot] = need
        req.state = "running"
        self.admit_log.append(req.rid)
        return True

    def preempt_slot(self, slot):
        req = self._running.pop(slot)
        self._free_blocks += self._held.pop(slot, 0)
        req.state = "queued"
        req.n_preempts += 1
        return req

    def complete(self, slot):
        req = self._running.pop(slot)
        self._free_blocks += self._held.pop(slot, 0)
        req.state = "completed"
        return req


def _req(rid, *, priority=0, deadline_s=math.inf, prompt_len=4,
         max_tokens=4, class_name="default"):
    return types.SimpleNamespace(
        rid=rid,
        prompt=np.zeros(prompt_len, np.int32),
        max_tokens=max_tokens,
        sla=SLA(deadline_s=deadline_s, priority=priority,
                class_name=class_name),
        state="queued", n_preempts=0, retries=0, t_deadline=math.inf,
    )


def _sched(**kw):
    kw.setdefault("clock", VirtualClock())
    return SLAScheduler(**kw)


class TestVirtualClock:
    def test_advance_and_call(self):
        clk = VirtualClock(5.0)
        assert clk() == 5.0
        assert clk.advance(2.5) == 7.5
        assert clk.now == 7.5


class TestAdmissionOrder:
    def test_edf_within_priority(self):
        sched = _sched()
        eng = _FakeEngine(slots=3)
        loose = _req(0, priority=1, deadline_s=9.0)
        best_effort = _req(1, priority=0, deadline_s=1.0)
        tight = _req(2, priority=1, deadline_s=2.0)
        for r in (loose, best_effort, tight):
            sched.enqueue(r)
        sched.tick(eng, None)
        # Priority first, earliest absolute deadline within a priority.
        assert eng.admit_log == [2, 0, 1]
        assert not sched.pending

    def test_no_head_of_line_blocking(self):
        """A resource-blocked big request must not starve the small one
        behind it within the same tick."""
        sched = _sched()
        eng = _FakeEngine(slots=2, blocks=2, paged=True)
        big = _req(0, priority=1, prompt_len=12, max_tokens=8)   # 5 blocks
        small = _req(1, priority=0, prompt_len=2, max_tokens=2)  # 1 block
        sched.enqueue(big)
        sched.enqueue(small)
        sched.tick(eng, None)
        assert eng.admit_log == [1]
        assert big.retries == 1          # backed off, not lost


class TestExpiry:
    def test_deadline_already_passed_expires_on_enqueue(self):
        sched = _sched()
        sched.clock.advance(10.0)
        late = _req(0, deadline_s=0.0)
        sched.enqueue(late)
        assert late.state == "expired"
        assert sched.stats["expired"] == 1
        assert not sched.pending

    def test_queued_request_expires_when_deadline_passes(self):
        sched = _sched(backoff_s=0.01)
        eng = _FakeEngine(slots=0)               # nothing ever admits
        req = _req(0, deadline_s=1.0)
        sched.enqueue(req)
        sched.tick(eng, None)                    # blocked -> retry heap
        assert req.state == "queued"
        sched.clock.advance(2.0)
        sched.tick(eng, None)                    # retry due, now hopeless
        assert req.state == "expired"
        assert sched.stats["expired"] == 1

    def test_service_time_ema_sheds_unfinishable_decode(self):
        sched = _sched()
        eng = _FakeEngine(slots=1)
        done = _req(0, max_tokens=4)
        sched.enqueue(done)
        sched.tick(eng, None)
        sched.clock.advance(10.0)                # 2.5 clock-units per token
        sched.on_complete(eng, done)
        assert sched._tpot_ema == pytest.approx(2.5)
        hopeless = _req(1, deadline_s=5.0, max_tokens=4)   # needs ~10
        sched.enqueue(hopeless)
        assert hopeless.state == "expired"
        fine = _req(2, deadline_s=20.0, max_tokens=4)
        sched.enqueue(fine)
        assert fine.state == "queued"

    def test_feasibility_oracle_sheds_doomed_uplinks(self):
        sched = _sched(feasibility=lambda req, remaining: 0.0,
                       feasibility_floor=0.0)
        doomed = _req(0, deadline_s=5.0)
        sched.enqueue(doomed)
        assert doomed.state == "expired"
        # Best-effort (infinite deadline) requests never consult the oracle.
        forever = _req(1)
        sched.enqueue(forever)
        assert forever.state == "queued"

    def test_protocol_feasibility_tracks_chaos_loss(self):
        from repro.core import link
        from repro.net import make_protocol

        loss = {"p": 0.0}
        fn = protocol_feasibility(
            make_protocol("unreliable"), 16, link.ChannelConfig(),
            loss_rate=lambda: loss["p"],
        )
        req = _req(0)
        assert fn(req, 10.0) == pytest.approx(1.0, abs=1e-9)
        loss["p"] = 1.0                          # mid-run channel collapse
        assert fn(req, 10.0) == 0.0


class TestAdmissionControl:
    def test_bounded_retry_then_terminal_reject(self):
        sched = _sched(max_retries=2, backoff_s=0.05, backoff_mult=2.0)
        eng = _FakeEngine(slots=0)
        req = _req(0)
        sched.enqueue(req)
        for _ in range(3):
            sched.tick(eng, None)
            sched.clock.advance(1.0)
        assert req.state == "rejected"
        assert req.retries == 3
        assert sched.stats["rejected"] == 1
        assert not sched.pending

    def test_backoff_delay_grows_and_caps(self):
        sched = _sched(backoff_s=0.1, backoff_mult=2.0, backoff_cap_s=0.3,
                       max_retries=100)
        eng = _FakeEngine(slots=0)
        req = _req(0)
        sched.enqueue(req)
        due = []
        for _ in range(4):
            sched.tick(eng, None)
            due.append(sched._retry[0][0] - sched.clock.now)
            sched.clock.advance(1.0)
        assert due == pytest.approx([0.1, 0.2, 0.3, 0.3])


class TestPreemptionPolicy:
    def _one_running(self, priority=0):
        sched = _sched(backoff_s=0.01)
        eng = _FakeEngine(slots=1, blocks=2, paged=True)
        low = _req(0, priority=priority, prompt_len=4, max_tokens=4)
        sched.enqueue(low)
        sched.tick(eng, None)
        assert low.state == "running"
        return sched, eng, low

    def test_higher_priority_preempts_and_victim_resumes(self):
        sched, eng, low = self._one_running(priority=0)
        hi = _req(1, priority=5, prompt_len=4, max_tokens=4)
        sched.enqueue(hi)
        sched.tick(eng, None)
        assert hi.state == "running"
        assert low.state == "queued" and low.n_preempts == 1
        assert sched.stats["preemptions"] == 1
        # Victim waits for the NEXT tick (anti-thrash), resumes when the
        # preemptor's resources free up.
        eng.complete(0)
        sched.on_complete(eng, hi)
        sched.tick(eng, None)
        assert low.state == "running"
        assert sched.stats["resumes"] == 1

    def test_equal_priority_never_preempts(self):
        sched, eng, low = self._one_running(priority=1)
        peer = _req(1, priority=1)
        sched.enqueue(peer)
        sched.tick(eng, None)
        assert low.state == "running"
        assert peer.state == "queued"
        assert sched.stats["preemptions"] == 0

    def test_preemption_disabled_backs_off_instead(self):
        sched = _sched(preemption=False, backoff_s=0.01)
        eng = _FakeEngine(slots=1, blocks=2, paged=True)
        low = _req(0, priority=0)
        sched.enqueue(low)
        sched.tick(eng, None)
        hi = _req(1, priority=5)
        sched.enqueue(hi)
        sched.tick(eng, None)
        assert low.state == "running" and hi.state == "queued"
        assert sched.stats["preemptions"] == 0

    def test_all_or_nothing_when_blocks_unattainable(self):
        """If evicting EVERY lower-priority slot still could not free
        enough blocks, nothing is evicted."""
        sched = _sched(backoff_s=0.01)
        eng = _FakeEngine(slots=2, blocks=4, paged=True)
        lo0 = _req(0, priority=0, prompt_len=4, max_tokens=4)   # 2 blocks
        lo1 = _req(1, priority=0, prompt_len=4, max_tokens=4)   # 2 blocks
        for r in (lo0, lo1):
            sched.enqueue(r)
        sched.tick(eng, None)
        giant = _req(2, priority=9, prompt_len=16, max_tokens=8)  # 6 blocks
        sched.enqueue(giant)
        sched.tick(eng, None)
        assert sched.stats["preemptions"] == 0
        assert lo0.state == "running" and lo1.state == "running"
        assert giant.state == "queued"

    def test_evicts_cheapest_victims_first(self):
        """Lowest priority, latest deadline goes first; stop as soon as
        the admission is satisfiable."""
        sched = _sched(backoff_s=0.01)
        eng = _FakeEngine(slots=2, blocks=4, paged=True)
        batch = _req(0, priority=0, deadline_s=math.inf)
        tight = _req(1, priority=1, deadline_s=1.0)
        for r in (batch, tight):
            sched.enqueue(r)
        sched.tick(eng, None)
        hi = _req(2, priority=5, prompt_len=4, max_tokens=4)
        sched.enqueue(hi)
        sched.tick(eng, None)
        assert hi.state == "running"
        assert batch.state == "queued"       # the best-effort one paid
        assert tight.state == "running"
        assert sched.stats["preemptions"] == 1


class TestClassReport:
    def test_hit_rate_counts_expired_and_rejected_as_misses(self):
        sched = _sched()
        eng = _FakeEngine(slots=1)
        ontime = _req(0, deadline_s=10.0, class_name="interactive")
        sched.enqueue(ontime)
        sched.tick(eng, None)
        eng.complete(0)
        sched.on_complete(eng, ontime)
        late = _req(1, deadline_s=0.0, class_name="interactive")
        sched.enqueue(late)                  # expires on the spot
        rep = sched.class_report()["interactive"]
        assert rep["terminal"] == 2
        assert rep["hits"] == 1
        assert rep["deadline_hit_rate"] == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# Integration layer: the real paged engine
# ---------------------------------------------------------------------------


def _setup_engine(channel="iid", loss_rate=0.3, **overrides):
    cfg = ARCHITECTURES["qwen1.5-0.5b"].reduced(
        attn_impl="flash_decode", **overrides
    )
    cfg = cfg.with_updates(
        link=dataclasses.replace(cfg.link, loss_rate=loss_rate,
                                 channel=channel)
    )
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompt(i, length, vocab):
    return np.asarray(
        jax.random.randint(
            jax.random.fold_in(jax.random.PRNGKey(7), i), (length,), 0,
            vocab, jnp.int32,
        )
    )


def _preempt_and_check(cfg, params, pool, lo_lengths, hi_length, tokens,
                       key):
    """Fill the pool with low-priority traffic, force a high-priority
    preemption mid-decode, drain, and require every request — victims
    included — to be greedy token-identical to the uninterrupted
    per-request reference."""
    eng = ContinuousEngine(cfg, pool)
    sched = SLAScheduler(backoff_s=1e-4, backoff_cap_s=1e-3,
                         max_retries=10_000)
    eng.attach_scheduler(sched)
    lo = SLA(priority=0, class_name="batch")
    hi = SLA(priority=5, class_name="interactive")
    lengths = list(lo_lengths) + [hi_length]
    reqs = [
        eng.submit(_prompt(i, L, cfg.vocab_size), tokens,
                   key=jax.random.fold_in(key, i), sla=lo)
        for i, L in enumerate(lo_lengths)
    ]
    eng.step(params)                     # admit the low-priority wave
    eng.step(params)                     # ...and decode a couple tokens
    i_hi = len(lo_lengths)
    reqs.append(
        eng.submit(_prompt(i_hi, hi_length, cfg.vocab_size), tokens,
                   key=jax.random.fold_in(key, i_hi), sla=hi)
    )
    eng.run(params)
    assert sched.stats["preemptions"] >= 1, sched.stats
    assert sched.stats["resumes"] >= 1, sched.stats
    assert all(r.state == "completed" for r in reqs)
    assert any(r.n_preempts > 0 for r in reqs[:-1])
    for i, (L, req) in enumerate(zip(lengths, reqs)):
        ref, _ = generate_reference(
            params, cfg, jnp.asarray(_prompt(i, L, cfg.vocab_size))[None],
            tokens, key=jax.random.fold_in(key, i),
        )
        np.testing.assert_array_equal(
            np.asarray(ref)[0], req.tokens,
            err_msg=f"request {i} (len {L}, preempts {req.n_preempts})",
        )
    assert eng.compiles == eng.num_buckets + 1
    return eng, sched


class TestPreemptResumeIdentity:
    @pytest.mark.parametrize("channel", ["iid", "ge"])
    def test_token_identity_iid_and_ge(self, channel):
        cfg, params = _setup_engine(channel=channel)
        pool = PoolConfig(max_slots=2, max_new=4, max_prompt=8,
                          min_bucket=8, paged=True, block_size=4)
        _preempt_and_check(cfg, params, pool, [4, 6], 5, 4,
                           jax.random.PRNGKey(42))

    def test_token_identity_int8_pool(self):
        cfg, params = _setup_engine(kv_cache_dtype="int8")
        pool = PoolConfig(max_slots=2, max_new=5, max_prompt=8,
                          min_bucket=8, paged=True, block_size=8)
        _preempt_and_check(cfg, params, pool, [4, 5], 6, 5,
                           jax.random.PRNGKey(9))

    def test_token_identity_windowed_wrap(self):
        """Victim resume with rotating windows wrapping across the block
        boundary (window=6, block_size=4): the re-admitted prefill must
        rebuild the wrapped layout exactly."""
        cfg = ARCHITECTURES["gemma3-12b"].reduced(attn_impl="flash_decode")
        pat = tuple(dataclasses.replace(s, window=6) if s.window else s
                    for s in cfg.unit_pattern)
        cfg = cfg.with_updates(unit_pattern=pat)
        cfg = cfg.with_updates(
            link=dataclasses.replace(cfg.link, loss_rate=0.3, channel="iid")
        )
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        pool = PoolConfig(max_slots=2, max_new=8, max_prompt=8,
                          min_bucket=4, paged=True, block_size=4)
        _preempt_and_check(cfg, params, pool, [3, 5], 4, 8,
                           jax.random.PRNGKey(3))


class TestSteadyStateCompileDiscipline:
    def test_no_recompile_with_scheduler_and_chaos(self):
        """Zero new XLA builds in steady state with the scheduler ticking,
        preemptions firing, and a chaos block squeeze breathing in and out
        of the pool."""
        cfg, params = _setup_engine()
        eng = ContinuousEngine(
            cfg,
            PoolConfig(max_slots=2, max_new=4, max_prompt=8, min_bucket=8,
                       paged=True, block_size=4),
        )
        sched = SLAScheduler(backoff_s=1e-4, backoff_cap_s=1e-3,
                             max_retries=10_000)
        eng.attach_scheduler(sched)
        key = jax.random.PRNGKey(0)
        lo = SLA(priority=0, class_name="batch")
        hi = SLA(priority=5, class_name="interactive")
        # Warm: the single bucket, the decode step, and a preemption.
        for i, L in enumerate([4, 6]):
            eng.submit(_prompt(i, L, cfg.vocab_size), 3,
                       key=jax.random.fold_in(key, i), sla=lo)
        eng.step(params)
        eng.step(params)
        eng.submit(_prompt(2, 5, cfg.vocab_size), 3,
                   key=jax.random.fold_in(key, 2), sla=hi)
        eng.run(params)
        assert sched.stats["preemptions"] >= 1
        assert eng.compiles == eng.num_buckets + 1
        warm_compiles = eng.compiles

        echaos = EngineChaos(
            eng, ChaosSchedule([block_pool_squeeze(0.0, 1.0, 0.5)])
        )
        work = [
            (_prompt(100 + i, 4 + i % 5, cfg.vocab_size), 2 + i % 3,
             jax.random.fold_in(key, 100 + i), hi if i % 3 == 0 else lo)
            for i in range(6)
        ]
        with no_recompile(engines=(eng,)):
            echaos.apply(0.5)            # squeeze holds half the pool
            for p, t, k, s in work:
                eng.submit(p, t, key=k, sla=s)
            for _ in range(4):
                eng.step(params)
            echaos.apply(2.0)            # window over: blocks come back
            done = eng.run(params)
        assert len(done) == 6
        assert all(r.state == "completed" for r in done)
        assert eng.compiles == warm_compiles == eng.num_buckets + 1
        assert echaos.held_blocks == 0


class TestPoolExhaustedBackpressure:
    def test_unscheduled_engine_raises_typed_backpressure(self):
        """Satellite 1: with no scheduler, a chaos squeeze pinning every
        free block turns head-of-line blocking into a typed, bounded
        ``PoolExhausted`` — and the budget re-arms after the raise."""
        cfg, params = _setup_engine()
        eng = ContinuousEngine(
            cfg,
            PoolConfig(max_slots=2, max_new=4, max_prompt=8, min_bucket=8,
                       paged=True, block_size=4, exhaust_wait_steps=5),
        )
        echaos = EngineChaos(
            eng, ChaosSchedule([block_pool_squeeze(0.0, 100.0, 1.0)])
        )
        req = eng.submit(_prompt(0, 4, cfg.vocab_size), 3,
                         key=jax.random.PRNGKey(1))
        eng._ensure(params)
        echaos.apply(0.0)                    # every free block is held
        with pytest.raises(PoolExhausted) as ei:
            for _ in range(50):
                eng.step(params)
        exc = ei.value
        assert exc.waited_steps == 6         # budget 5, raised on the 6th
        assert exc.queued == 1
        assert exc.free_slots == 2
        assert exc.free_blocks == 0
        assert exc.need_blocks == eng.blocks_needed(4, 3) > 0
        assert "SLAScheduler" in str(exc)
        # Budget re-armed: another full wait before the next raise.
        with pytest.raises(PoolExhausted) as ei2:
            for _ in range(50):
                eng.step(params)
        assert ei2.value.waited_steps == 6
        # Release the squeeze and the very same queue drains normally.
        echaos.release_all()
        done = eng.run(params)
        assert [r.rid for r in done] == [req.rid]
        assert req.state == "completed"
        assert req.tokens is not None and len(req.tokens) == 3
