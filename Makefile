# Convenience entry points; each target mirrors exactly what CI runs.
PY ?= python3

.PHONY: lint baseline test

lint:                        ## static invariant checker (RPA001-RPA006)
	PYTHONPATH=src $(PY) -m repro.analysis src tests benchmarks

baseline:                    ## accept current findings as the tolerated set
	PYTHONPATH=src $(PY) -m repro.analysis src tests benchmarks --write-baseline

test:                        ## tier-1 tests
	PYTHONPATH=src $(PY) -m pytest -x -q
