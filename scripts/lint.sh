#!/usr/bin/env bash
# Run the repro.analysis static invariant checker the same way CI does.
# Pure stdlib: needs python3, nothing installed.
#
#   scripts/lint.sh                 # check src tests benchmarks vs baseline
#   scripts/lint.sh --no-baseline   # show every finding, baselined or not
#   scripts/lint.sh --write-baseline  # accept current findings as tolerated
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src exec python3 -m repro.analysis src tests benchmarks "$@"
